"""kernels/tree_descend tests: interpret-mode parity of the fused
descent+probe kernel and the segmented frontier-compaction kernel vs their
jnp oracles, the tiled rank-select vs the pairwise range_scan kernel, the
narrow end-to-end path vs the int64 ref path, and the trace-level
acceptance checks (no sort in the scan descent; the occ early-exit
accounting lives in test_forest.py)."""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    ABForest,
    ABTree,
    DictOracle,
    EMPTY,
    NOTFOUND,
    OP_DELETE,
    OP_FIND,
    OP_INSERT,
    OP_RANGE,
    TreeConfig,
)
from repro.core.abtree import frontier_expand
from repro.core.oracle import check_invariants
from repro.kernels.range_scan.kernel import range_scan_pallas
from repro.kernels.tree_descend import (
    descend_probe,
    descend_probe_pallas,
    descend_probe_ref,
    frontier_compact,
    frontier_compact_pallas,
    frontier_compact_ref,
)

SMALL = TreeConfig(capacity=512, b=8, a=2, max_height=12)


def _grown_tree(n_keys=300, seed=0, key_lim=10**6, cfg=SMALL):
    """A multi-level tree with deletions (EMPTY holes in leaves)."""
    rng = np.random.default_rng(seed)
    t = ABTree(cfg)
    keys = rng.choice(key_lim, size=n_keys, replace=False).astype(np.int64)
    t.apply_round(np.full(n_keys, OP_INSERT, np.int32), keys, keys * 3)
    drop = keys[: n_keys // 4]
    t.apply_round(np.full(drop.size, OP_DELETE, np.int32), drop, np.zeros_like(drop))
    return t, np.setdiff1d(keys, drop)


# ---------------------------------------------------------------------------
# fused descent + probe
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bsz", [1, 7, 64, 200])
def test_descend_probe_kernel_matches_ref(bsz):
    """Kernel vs jnp oracle on a real grown pool: leaf ids, found, slot and
    value must match lane-for-lane, including misses, EMPTY queries (NOP
    lanes) and keys routed to leaves with free-slot holes."""
    t, live = _grown_tree()
    rng = np.random.default_rng(bsz)
    q = rng.choice(live, size=bsz).astype(np.int64)
    q[bsz // 3 :: 3] = rng.integers(0, 10**6, len(q[bsz // 3 :: 3]))  # misses
    if bsz > 2:
        q[-1] = int(EMPTY)  # masked NOP lane convention
    s = t.state
    args = (s.keys, s.vals, s.children, s.is_leaf, s.root, jnp.asarray(q))
    kw = dict(max_height=t.cfg.max_height, notfound=NOTFOUND)
    ref = descend_probe_ref(*args, **kw)
    got = descend_probe(*args, **kw, narrow=True)
    for g, r, name in zip(got, ref, ("leaf", "found", "slot", "val")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r), err_msg=name)
    # the TreeState-bound host wrappers are thin views over the same refs —
    # pin the anti-drift claim by comparing them against the kernel too.
    from repro.core.abtree import descend as host_descend, probe as host_probe

    leaf_h = host_descend(s, jnp.asarray(q), t.cfg)
    found_h, slot_h, val_h = host_probe(s, leaf_h, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(leaf_h))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(found_h))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(slot_h))
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(val_h))


def test_descend_probe_x64_int32_pins():
    """Under jax_enable_x64 (forced by repro.core import) every in-kernel
    reduction must stay int32: outputs of the raw Pallas call are int32 and
    bit-equal to the ref run on int32 inputs."""
    t, live = _grown_tree(n_keys=120)
    s = t.state
    empty32 = np.iinfo(np.int32).max
    pk = jnp.where(s.keys == EMPTY, empty32, s.keys).astype(jnp.int32)
    pv = s.vals.astype(jnp.int32)
    q = jnp.asarray(live[:32].astype(np.int32))
    leaf, found, slot, val = descend_probe_pallas(
        pk, pv, s.children.astype(jnp.int32), s.is_leaf, s.root, q,
        max_height=t.cfg.max_height, interpret=True,
    )
    assert leaf.dtype == slot.dtype == val.dtype == jnp.int32
    ref = descend_probe_ref(
        pk, pv, s.children, s.is_leaf, s.root, q,
        max_height=t.cfg.max_height, notfound=jnp.int32(-1),
    )
    np.testing.assert_array_equal(np.asarray(leaf), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(found), np.asarray(ref[1]))
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(ref[2]))
    np.testing.assert_array_equal(
        np.asarray(val)[np.asarray(found)],
        np.asarray(ref[3])[np.asarray(ref[1])],
    )


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_narrow_tree_oracle_equivalent(mode):
    """ABTree(narrow=True) routes every descent through the Pallas kernel
    and must stay oracle-equivalent through mixed rounds with splits,
    deletes, deferred-insert retries and rebalancing."""
    rng = np.random.default_rng(7)
    t = ABTree(SMALL, mode=mode, narrow=True)
    o = DictOracle()
    for r in range(8):
        bsz = 64
        ops = rng.choice([OP_INSERT, OP_DELETE, OP_FIND], bsz).astype(np.int32)
        keys = rng.integers(0, 500, bsz).astype(np.int64)
        vals = rng.integers(0, 1000, bsz).astype(np.int64)
        got = t.apply_round(ops, keys, vals)
        wres, wfound = o.apply_round(ops, keys, vals)
        np.testing.assert_array_equal(np.asarray(got.results), wres)
        np.testing.assert_array_equal(np.asarray(got.found), wfound)
    assert t.items() == o.items()
    check_invariants(t.state, t.cfg)


def test_narrow_tree_engages_descend_kernel(monkeypatch):
    """The narrow tree must actually dispatch the Pallas descent (spy), and
    a narrow=False tree must not."""
    import repro.kernels.tree_descend.ops as td_ops

    calls = []
    orig = td_ops.descend_probe_pallas

    def spy(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(td_ops, "descend_probe_pallas", spy)
    for narrow, expect in ((True, True), (False, False)):
        before = len(calls)
        t = ABTree(TreeConfig(capacity=256, b=8, a=2, max_height=11), narrow=narrow)
        t.apply_round([OP_INSERT] * 3, [1, 2, 3], [1, 2, 3])
        assert (len(calls) > before) == expect, f"narrow={narrow}"


def test_narrow_scan_alone_keeps_jnp_compaction(monkeypatch):
    """PR-1 contract pin: ``narrow_scan=True`` (without ``narrow``) opts
    only the scan *gather* into its kernel — frontier compaction must stay
    on the jnp path; the full ``narrow`` gate engages the Pallas compaction."""
    import repro.kernels.tree_descend.ops as td_ops

    calls = []
    orig = td_ops.frontier_compact_pallas

    def spy(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(td_ops, "frontier_compact_pallas", spy)
    cfg = TreeConfig(capacity=256, b=8, a=2, max_height=11)
    for kwargs, expect in (
        (dict(narrow_scan=True), False),
        (dict(narrow=True), True),
    ):
        before = len(calls)
        t = ABTree(cfg, **kwargs)
        t.apply_round([OP_INSERT] * 4, [1, 2, 3, 4], [1, 2, 3, 4])
        # scan_cap unique per gate flavor forces a fresh scan-phase trace
        t.scan_round([0], [10], cap=23 if expect else 29)
        assert (len(calls) > before) == expect, f"{kwargs}"


def test_narrow_forest_oracle_equivalent():
    """vmapped narrow descents across shards (the forest search path)."""
    rng = np.random.default_rng(11)
    f = ABForest(n_shards=4, cfg=SMALL, key_space=(0, 2000), narrow=True)
    o = DictOracle()
    for r in range(5):
        bsz = 48
        ops = rng.choice([OP_INSERT, OP_DELETE, OP_FIND, OP_RANGE], bsz).astype(np.int32)
        keys = rng.integers(0, 2000, bsz).astype(np.int64)
        vals = rng.integers(0, 900, bsz).astype(np.int64)
        vals[ops == OP_RANGE] = rng.integers(0, 200, int((ops == OP_RANGE).sum()))
        got = f.apply_round(ops, keys, vals, scan_cap=256)
        wres, wfound, _ = o.apply_mixed_round(ops, keys, vals, cap=256)
        np.testing.assert_array_equal(np.asarray(got.results), wres)
        np.testing.assert_array_equal(np.asarray(got.found), wfound)
    assert f.items() == o.items()


# ---------------------------------------------------------------------------
# segmented frontier compaction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "bsz,m,f,density",
    [(1, 72, 8, 0.1), (8, 72, 8, 0.5), (16, 288, 32, 0.2), (5, 1152, 128, 0.05),
     (4, 144, 16, 1.0), (4, 144, 16, 0.0)],
)
def test_frontier_compact_matches_argsort_ref(bsz, m, f, density):
    """Both sort-free paths (jnp cumsum+scatter, Pallas kernel) must be
    bit-identical to the stable-argsort oracle, including overflow rows
    (> f valid candidates), all-valid and all-invalid rows."""
    rng = np.random.default_rng(int(m * f * (1 + density * 10)))
    cand = rng.integers(0, 4096, (bsz, m)).astype(np.int32)
    valid = rng.random((bsz, m)) < density
    args = (jnp.asarray(cand), jnp.asarray(valid))
    want = frontier_compact_ref(*args, f, scratch=4097)
    for pallas in (False, True):
        got = frontier_compact(*args, f, scratch=4097, use_pallas=pallas)
        for g, w, name in zip(got, want, ("frontier", "valid", "overflow")):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w), err_msg=f"pallas={pallas} {name}"
            )


def test_frontier_compact_x64_int32_pins():
    """x64 weak-typing pin: the raw kernel outputs must be int32."""
    cand = jnp.asarray(np.arange(64, dtype=np.int32).reshape(2, 32))
    valid = jnp.asarray(np.tile([True, False], 32).reshape(2, 32))
    frontier, fvalid, total = frontier_compact_pallas(cand, valid, f=8)
    assert frontier.dtype == jnp.int32 and total.dtype == jnp.int32
    assert int(total[0]) == 16 and bool(fvalid[0, 7])


def test_frontier_compact_stability():
    """Compaction preserves candidate order (the argsort it replaces was
    stable): ascending markers must come out ascending."""
    m, f = 40, 16
    cand = jnp.asarray(np.arange(m, dtype=np.int32)[None, :])
    valid = jnp.asarray((np.arange(m) % 3 == 1)[None, :])
    for pallas in (False, True):
        fr, fv, _ = frontier_compact(cand, valid, f, scratch=-5, use_pallas=pallas)
        got = np.asarray(fr)[0][np.asarray(fv)[0]]
        assert list(got) == sorted(got) and list(got) == list(range(1, m, 3))


# ---------------------------------------------------------------------------
# tiled rank-select (kernels/range_scan)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "bsz,n,cap,tile", [(4, 128, 16, 32), (2, 512, 128, 128), (2, 1024, 128, 128),
                       (1, 1024, 256, 256), (3, 96, 33, 64)],
)
def test_tiled_rank_select_bit_exact(bsz, n, cap, tile):
    """The (n/T)×(n/T)-tiled rank-select must match the pairwise kernel
    bit-exactly (ranks are integer partial sums over disjoint tiles) for n
    up to 1024, including non-multiple-of-T widths (INT32_MAX padding)."""
    rng = np.random.default_rng(n + cap)
    empty32 = np.iinfo(np.int32).max
    keys = np.stack([rng.choice(10**7, size=n, replace=False) for _ in range(bsz)])
    keys = np.where(rng.random((bsz, n)) < 0.3, empty32, keys).astype(np.int32)
    vals = rng.integers(0, 10**6, (bsz, n)).astype(np.int32)
    lo = rng.integers(0, 10**7, bsz).astype(np.int32)
    hi = lo + rng.integers(0, 10**7, bsz).astype(np.int32)
    args = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo), jnp.asarray(hi))
    pairwise = range_scan_pallas(*args, cap=cap, tile_n=-1)
    tiled = range_scan_pallas(*args, cap=cap, tile_n=tile)
    auto = range_scan_pallas(*args, cap=cap)
    for p, t_, a, name in zip(pairwise, tiled, auto, ("keys", "vals", "count", "trunc")):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(t_), err_msg=name)
        np.testing.assert_array_equal(np.asarray(p), np.asarray(a), err_msg=name)
    assert pairwise[0].dtype == jnp.int32  # x64 pin on the store dtype


# ---------------------------------------------------------------------------
# trace-level acceptance: the scan descent is sort-free
# ---------------------------------------------------------------------------


def _hlo_of_frontier_expand(narrow: bool) -> str:
    t, _ = _grown_tree(n_keys=64)
    fe = jax.jit(
        functools.partial(frontier_expand, frontier_cap=8, narrow=narrow),
        static_argnums=(1,),
    )
    lo = jnp.asarray([0, 100], jnp.int64)
    hi = jnp.asarray([50, 10**6], jnp.int64)
    return fe.lower(t.state, t.cfg, lo, hi).as_text()


@pytest.mark.parametrize("narrow", [False, True])
def test_scan_descent_trace_has_no_sort(narrow):
    """The per-level frontier compaction used a stable XLA argsort (24× per
    scan); both replacement paths must lower with zero sort ops."""
    from repro.obs.hlo_audit import assert_no_sort

    txt = _hlo_of_frontier_expand(narrow)
    assert_no_sort(txt, f"scan descent trace (narrow={narrow})")


def test_narrow_scan_phase_trace_has_no_sort():
    """With the full narrow gate the entire scan phase (descent + frontier
    compaction + rank-select gather) is sort-free; the int64 ref path keeps
    exactly one sort (the rank-select oracle's argsort)."""
    from repro.core import rounds as R
    from repro.obs.hlo_audit import assert_no_sort, count_ops

    t, _ = _grown_tree(n_keys=64)
    lo = jnp.asarray([0, 100], jnp.int64)
    hi = jnp.asarray([50, 10**6], jnp.int64)
    sid = jnp.zeros(2, jnp.int32)  # flat ragged phase: lanes carry shard ids
    txt_narrow = R._phase_scan_flat.lower(
        t.stacked, t.cfg, sid, lo, hi, 8, 16, True, True
    ).as_text()
    txt_ref = R._phase_scan_flat.lower(
        t.stacked, t.cfg, sid, lo, hi, 8, 16, False, False
    ).as_text()
    assert_no_sort(txt_narrow, "narrow scan phase")
    # descent contributes none — only the rank-select oracle's argsort
    assert count_ops(txt_ref, ("stablehlo.sort",))["stablehlo.sort"] <= 1


def test_hlo_audit_scan_paths_sort_free():
    """The shared audit (the surface ``kernels_bench`` records) agrees:
    both scan-path programs lower sort-free, and the narrow point-op
    search never needs MORE gathers than the int64 oracle."""
    from repro.obs.hlo_audit import audit_search_phases

    audit = audit_search_phases()
    assert audit["scan_descent"]["stablehlo.sort"] == 0
    assert audit["scan_phase.narrow"]["stablehlo.sort"] == 0
    assert (
        audit["search.narrow"]["stablehlo.gather"]
        <= audit["search.ref"]["stablehlo.gather"]
    )
