"""Durability example: crash injection at every protocol step, recovery to
the last committed round, and the p-Elim vs p-OCC flush-cost gap.

    PYTHONPATH=src python examples/durable_store.py
"""
import tempfile

import numpy as np

from repro.core import CrashPoint, DurableABTree, OP_DELETE, OP_INSERT, TreeConfig, recover
from repro.core.durable import SimulatedCrash


def main():
    rng = np.random.default_rng(0)

    # --- crash mid-manifest: the round never becomes durable -------------------
    d = tempfile.mkdtemp(prefix="crash_demo_")
    t = DurableABTree(
        d, TreeConfig(capacity=1024), crash=CrashPoint("mid_manifest", at_commit=2)
    )
    t.apply_round([OP_INSERT] * 4, [1, 2, 3, 4], [10, 20, 30, 40])  # commit 1 ✓
    try:
        t.apply_round([OP_INSERT] * 2, [5, 6], [50, 60])  # commit 2 ✗ (crash)
    except SimulatedCrash as e:
        print("crashed:", e)
    r = recover(d)
    print("recovered (crashed round absent):", r.tree.items())
    assert r.tree.items() == {1: 10, 2: 20, 3: 30, 4: 40}

    # --- p-Elim vs p-OCC on a hot-key churn workload ---------------------------
    stats = {}
    for mode in ("elim", "occ"):
        d2 = tempfile.mkdtemp(prefix=f"p{mode}_")
        dt = DurableABTree(d2, TreeConfig(capacity=1024), mode=mode)
        for _ in range(4):
            ops = [OP_INSERT, OP_DELETE] * 16
            keys = (np.minimum(rng.zipf(1.6, 32), 8)).tolist()
            dt.apply_round(ops, keys, list(range(32)))
        stats[mode] = dt.stats()
        print(
            f"p-{mode}: commits={stats[mode]['commits']} "
            f"fsyncs={stats[mode]['fsyncs']} flush_bytes={stats[mode]['flush_bytes']}"
        )
    assert stats["elim"]["fsyncs"] < stats["occ"]["fsyncs"]
    print("p-Elim needs fewer flushes — the paper's Table 1 effect")


if __name__ == "__main__":
    main()
