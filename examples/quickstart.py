"""Quickstart: the Elim-ABtree as a dictionary, elimination in action,
durability, and a tiny LM train step — in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ABTree, DurableABTree, OP_DELETE, OP_INSERT, TreeConfig, recover


def main():
    # --- 1. batched dictionary ------------------------------------------------
    tree = ABTree(TreeConfig(capacity=1024), mode="elim")
    tree.insert(42, 4200)
    print("find(42) →", tree.find(42))

    # --- 2. publishing elimination: 64 concurrent ops on ONE hot key ----------
    ops = [OP_INSERT, OP_DELETE] * 32
    keys = [7] * 64
    vals = list(range(64))
    tree.apply_round(ops, keys, vals)
    s = tree.stats()
    print(f"64 ops on one key → physical slot writes: {s['slot_writes'] - 2}, "
          f"eliminated: {s['eliminated']}")

    # --- 3. durability (link-and-persist) -------------------------------------
    import tempfile

    d = tempfile.mkdtemp(prefix="elim_tree_")
    dt = DurableABTree(d, TreeConfig(capacity=1024))
    dt.apply_round([OP_INSERT] * 3, [1, 2, 3], [10, 20, 30])
    rec = recover(d)
    print("recovered contents:", rec.tree.items())

    # --- 4. one LM train step (reduced qwen2) ----------------------------------
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import backbone, init_params, loss_fn, reduced

    cfg = reduced(get_config("qwen2-0.5b"), n_layers=2)
    params = init_params(backbone.model_spec(cfg))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)}
    loss, metrics = loss_fn(params, batch, cfg)
    print(f"qwen2(reduced) initial loss: {float(loss):.3f} "
          f"(≈ ln(vocab) = {np.log(cfg.vocab):.3f})")


if __name__ == "__main__":
    main()
