"""End-to-end training driver: data pipeline → sharded train step →
durable checkpoints → auto-resume.

Default is a CPU-sized model for this container; ``--preset 100m`` trains a
~100M-parameter qwen2-family config for a few hundred steps (the
full-scale driver used on a real slice — identical code path, bigger
shapes).

    PYTHONPATH=src python examples/train_lm.py                 # tiny, 40 steps
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --fail-at 20    # crash+resume demo
"""
import argparse
import json

from repro.configs import get_config
from repro.data import make_data_iter
from repro.launch.mesh import make_host_mesh
from repro.models import reduced
from repro.train import Trainer, TrainerConfig
from repro.train.trainer import SimulatedFailure


def make_cfg(preset: str):
    base = get_config("qwen2-0.5b")
    if preset == "tiny":
        return reduced(base, n_layers=2)
    if preset == "100m":
        # ~100M params: 12L d768 12H kv4
        return base.replace(
            n_layers=12, d_model=768, n_heads=12, n_kv=4, d_ff=2048,
            vocab=32768, dtype="float32", rules="tp",
        )
    raise SystemExit(f"unknown preset {preset}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    mesh = make_host_mesh()
    tcfg = TrainerConfig(
        ckpt_dir=args.ckpt,
        max_steps=args.steps,
        ckpt_every=max(args.steps // 4, 5),
        fail_at_step=args.fail_at,
        log_every=5,
    )
    mk_iter = lambda step: make_data_iter(cfg, batch=args.batch, seq=args.seq, start_step=step)

    trainer = Trainer(cfg, tcfg, mesh, mk_iter)
    if trainer.resumed_from is not None:
        print(f"[resume] from durable checkpoint @ step {trainer.resumed_from}")
    try:
        out = trainer.run()
    except SimulatedFailure as e:
        print(f"[crash] {e} — rerun this script to observe auto-resume")
        return
    print(json.dumps(out, indent=1, default=str))


if __name__ == "__main__":
    main()
