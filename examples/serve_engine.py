"""Serving example: continuous batching + paged KV + Elim-ABtree prefix
index.  A skewed request mix (hot shared system prompt) shows prefix-cache
hits and the index's elimination stats.

    PYTHONPATH=src python examples/serve_engine.py
"""
import json

import numpy as np

from repro.configs import get_config
from repro.models import reduced
from repro.serve import Request, ServeEngine
from repro.serve.pages import PAGE


def main():
    cfg = reduced(get_config("qwen2-0.5b"), n_layers=1)
    eng = ServeEngine(cfg, max_batch=4, s_max=8 * PAGE, n_pages=128, index_mode="elim")
    rng = np.random.default_rng(0)
    hot = rng.integers(0, cfg.vocab, PAGE).tolist()  # shared system prompt
    for rid in range(12):
        prompt = list(hot) if rng.random() < 0.75 else rng.integers(0, cfg.vocab, PAGE).tolist()
        eng.submit(Request(rid=rid, prompt=prompt, max_new=4))
    done = eng.run_until_done()
    print(f"served {len(done)} requests")
    print(json.dumps(eng.stats(), indent=1))


if __name__ == "__main__":
    main()
